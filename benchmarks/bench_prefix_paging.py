"""Paged-KV prefix-reuse benchmark (radix-tree prefix cache tentpole).

Two workloads, four engine configurations:

  partial-overlap   Every prompt opens with the same instruction, then one
                    of three per-category few-shot blocks, then a short
                    per-row tail.  The batch-wide common prefix (all the
                    "exact" string memo can see) is just the instruction;
                    the per-category blocks are overlap that only a radix
                    tree over token sequences can discover and share.
  fork (n_samples)  Self-consistency sampling: each row fans out into 4
                    streams.  Paged-radix forks share every prompt page
                    copy-on-write; dense replays the full prompt prefill
                    per stream.

Systems:
  dense     kv_layout="dense": per-slot max_len cache rows, full-prompt
            prefill per stream.  Reference rows + fork baseline.
  exact     kv_layout="paged", prefix_cache_mode="exact": PR-5 behaviour —
            the batch-common carved prefix resolves through the memo; each
            slot still prefills its own few-shot block.
  radix     prefix_cache_mode="radix": per-row deepest-node match against
            the refcounted radix tree; only the unseen suffix prefills and
            only newly materialized full pages are committed back.
  radix_q8  radix + kv_quant="int8": committed (frozen) pages stored as
            int8 with a per-page scale, dequantized inside paged attention.
            Rows may drift (documented below); KV bytes drop further.

Asserts the acceptance criteria: dense == exact == radix rows byte-for-byte
(float32 engines — bfloat16 near-ties would make equality a coin toss),
radix >= 2x fewer prefill tokens and >= 1.5x lower peak KV bytes than the
exact baseline (which doubles as the peak-KV regression guard: paged-radix
must never exceed paged-exact), and fork prefill/KV well under dense.
int8 row drift is expected and reported; the element-wise dequant error
bound (|x - deq| <= scale/2) is asserted in tests/test_radix_kv.py.
"""
import time

import repro.configs as C
from repro.core.database import IPDB
from repro.core.executors import JaxExecutor
from repro.relational.table import Table
from repro.serving.engine import InferenceEngine

INSTRUCTION = ("You are the product catalog annotator. For each row, read "
               "the few-shot examples, then the item name, and answer with "
               "the requested field. Follow the output schema exactly, "
               "emit JSON only, and never add commentary. ")

# Three few-shot blocks ~0.4 KiB each (tokens are bytes): long enough that
# the per-category overlap spans several 64-token pages, and diverging at
# the first character so the batch-wide common prefix stops at the block.
FEWSHOT = [
    head + " ".join(f"example {i}: the {noun} number {i} is labeled "
                    f"{label}{i % 7};" for i in range(8))
    for head, noun, label in (("A)", "appliance", "alpha"),
                              ("B)", "beverage", "beta"),
                              ("C)", "cable", "gamma"))
]

QUERY = ("SELECT name, LLM anno (PROMPT '" + INSTRUCTION +
         "{{fewshot}} guess the {color VARCHAR} of {{name}}') AS color "
         "FROM Items")

SYSTEMS = {
    "dense": dict(kv_layout="dense"),
    "exact": dict(kv_layout="paged", prefix_cache_mode="exact"),
    "radix": dict(kv_layout="paged", prefix_cache_mode="radix"),
    "radix_q8": dict(kv_layout="paged", prefix_cache_mode="radix",
                     kv_quant="int8"),
}


def _engine(**kw) -> InferenceEngine:
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                compute_dtype="float32")
    return InferenceEngine(cfg, max_len=1024, seed=0, page_size=64, **kw)


def _db(n: int, eng: InferenceEngine, n_samples: int = 1) -> IPDB:
    db = IPDB()
    db.register_table("Items", Table.from_rows(
        [{"fewshot": FEWSHOT[i % 3], "name": f"item {i:02d}"}
         for i in range(n)]))
    db.register_table("WarmItems", Table.from_rows(
        [{"fewshot": FEWSHOT[i], "name": f"warm {i}"} for i in range(3)]))

    def factory(entry):
        ex = JaxExecutor(eng)
        ex.configure(dict(entry.options))
        return ex

    db.register_executor("bench_jax", factory)
    db.sql("CREATE LLM MODEL anno PATH 'custom:bench_jax' ON PROMPT "
           "OPTIONS { 'batch_size': 1, 'max_str': 8, 'temperature': 0.0, "
           f"'num_slots': 8, 'max_tokens': 64, 'n_samples': {n_samples} }}")
    db.set_option("batch_size", 1)
    # one dispatch batch with every row: the continuous batcher fills all
    # its slots at once, so per-slot prompt duplication (what the radix
    # tree removes) is actually on the table.  Cross-batch reuse is still
    # exercised: the warmup query leaves the memo/tree populated.
    db.set_option("max_dispatch_calls", 0)
    return db


def _peak_kv(eng: InferenceEngine) -> int:
    # paged: lifetime running peak of in-use pool bytes; dense: the
    # constant full-cache footprint folded into the engine totals
    return eng.kv_peak_bytes or eng.total.kv_bytes


def run(quick: bool = False):
    n = 9 if quick else 18
    n_fork = 3 if quick else 6

    engines = {name: _engine(**kw) for name, kw in SYSTEMS.items()}
    walls, results = {}, {}
    for name, eng in engines.items():
        db = _db(n, eng)
        # untimed warmup on one row per category: pays the jit compiles and
        # leaves instruction + few-shot pages resident in the memo/tree —
        # the steady state a serving session runs in
        db.sql(QUERY.replace("FROM Items", "FROM WarmItems"))
        eng.kv_peak_bytes = 0          # peak of the timed query only
        t0 = time.time()
        results[name] = db.sql(QUERY)
        walls[name] = time.time() - t0
        db.close()

    rows_ref = results["dense"].table.rows()
    for name in ("exact", "radix"):
        if results[name].table.rows() != rows_ref:
            raise AssertionError(f"{name} changed decoded rows vs dense")

    pf = {k: r.stats.prefill_tokens for k, r in results.items()}
    kv = {k: _peak_kv(engines[k]) for k in results}
    if not pf["radix"] * 2 <= pf["exact"]:
        raise AssertionError(
            f"radix prefill not 2x lower: {pf['radix']} vs {pf['exact']}")
    if not kv["radix"] * 1.5 <= kv["exact"]:   # also the regression guard
        raise AssertionError(
            f"radix peak KV not 1.5x lower: {kv['radix']} vs {kv['exact']}")
    if results["radix"].stats.radix_hit_tokens <= 0:
        raise AssertionError("radix run never matched a tree node")
    # int8: same reuse economics at lower KV bytes; rows may drift within
    # the quantization error bound, so report rather than require equality
    if kv["radix_q8"] >= kv["radix"]:
        raise AssertionError(
            f"int8 pages did not cut KV: {kv['radix_q8']} vs {kv['radix']}")
    q8_rows = results["radix_q8"].table.rows()
    if len(q8_rows) != len(rows_ref):
        raise AssertionError("radix_q8 dropped rows")
    q8_drift = sum(a != b for a, b in zip(q8_rows, rows_ref)) / len(rows_ref)

    # fork workload: n_samples=4 self-consistency, greedy (so every stream
    # agrees and the vote reproduces the single-sample rows)
    fork_res, fork_walls = {}, {}
    for name in ("dense", "radix"):
        eng = engines[name]
        db = _db(n_fork, eng, n_samples=4)
        eng.kv_peak_bytes = 0
        t0 = time.time()
        fork_res[name] = db.sql(QUERY)
        fork_walls[name] = time.time() - t0
        db.close()
    if fork_res["radix"].table.rows() != fork_res["dense"].table.rows():
        raise AssertionError("forked radix changed decoded rows vs dense")
    fpf = {k: r.stats.prefill_tokens for k, r in fork_res.items()}
    fkv = {"dense": engines["dense"].total.kv_bytes,
           "radix": engines["radix"].kv_peak_bytes}
    if not fpf["radix"] * 2 <= fpf["dense"]:
        raise AssertionError(
            f"fork prefill not 2x lower: {fpf['radix']} vs {fpf['dense']}")
    if not fkv["radix"] * 1.5 <= fkv["dense"]:
        raise AssertionError(
            f"fork peak KV not 1.5x lower: {fkv['radix']} vs {fkv['dense']}")

    rows = []
    for name, r in results.items():
        s = r.stats
        hit_depth = s.radix_hit_tokens / max(1, s.prefix_hits)
        rows.append((
            f"prefix_paging.{name}",
            round(walls[name] / max(1, s.llm_calls) * 1e6, 1),
            f"wall_s={walls[name]:.2f};prefill_tokens={s.prefill_tokens};"
            f"decode_tokens={s.decode_tokens};peak_kv_bytes={kv[name]};"
            f"prefix_hits={s.prefix_hits};"
            f"radix_hit_tokens={s.radix_hit_tokens};"
            f"radix_hit_depth={hit_depth:.0f};calls={s.llm_calls}"))
    for name, r in fork_res.items():
        s = r.stats
        rows.append((
            f"prefix_paging.fork_{name}",
            round(fork_walls[name] / max(1, s.llm_calls) * 1e6, 1),
            f"wall_s={fork_walls[name]:.2f};n_samples=4;"
            f"prefill_tokens={s.prefill_tokens};"
            f"decode_tokens={s.decode_tokens};peak_kv_bytes={fkv[name]};"
            f"radix_hit_tokens={s.radix_hit_tokens};calls={s.llm_calls}"))
    rows.append((
        "prefix_paging.savings",
        round((walls["exact"] - walls["radix"]) * 1e6, 1),
        f"prefill_ratio={pf['exact'] / max(1, pf['radix']):.2f};"
        f"kv_ratio={kv['exact'] / max(1, kv['radix']):.2f};"
        f"q8_kv_ratio={kv['exact'] / max(1, kv['radix_q8']):.2f};"
        f"q8_row_drift={q8_drift:.2f};"
        f"fork_prefill_ratio={fpf['dense'] / max(1, fpf['radix']):.2f};"
        f"fork_kv_ratio={fkv['dense'] / max(1, fkv['radix']):.2f}"))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
