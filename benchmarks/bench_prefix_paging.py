"""Paged-KV shared-prefix benchmark (paged block-table tentpole).

Workload: one long shared instruction × many short rows, marshaled into
per-row prompts (batch_size=1) so every dispatched prompt repeats the same
instruction prefix — the worst case the dense layout pays for and the best
case for prefix paging.

Systems:
  dense   kv_layout="dense": the continuous batcher prefills the FULL
          prompt (instruction + row) into every slot's max_len cache row;
          KV memory is num_slots × max_len regardless of fill.
  paged   kv_layout="paged": the JaxExecutor carves the common instruction
          prefix out of the marshaled prompts, the engine prefills it ONCE
          into pool pages, and every slot's block table references those
          pages zero-copy; decode attention walks only occupied blocks.

The run asserts the acceptance criteria: byte-identical decoded rows while
the paged layout shows strictly lower prefill tokens and strictly lower
peak KV-cache bytes; wall time is reported for the trajectory.

Engines compute in float32 here: dense and paged attention are
mathematically identical but travel different reduction paths, and the
row-equality assertion needs the two layouts' near-ties to resolve the
same way (bfloat16's ~1e-2 rounding would make that a coin toss).
"""
import time

import repro.configs as C
from repro.core.database import IPDB
from repro.core.executors import JaxExecutor
from repro.relational.table import Table
from repro.serving.engine import InferenceEngine

INSTRUCTION = ("You are the product catalog annotator. For each row, read "
               "the item name carefully and answer with the requested "
               "field. Follow the output schema exactly, emit JSON only, "
               "and never add commentary. ")

QUERY = ("SELECT name, LLM anno (PROMPT '" + INSTRUCTION +
         "guess the {color VARCHAR} of {{name}}') AS color FROM Items")


def _db(n: int, layout: str, engines: dict) -> IPDB:
    db = IPDB()
    db.register_table("Items", Table.from_rows(
        [{"name": f"item {i}"} for i in range(n)]))
    db.register_table("WarmItems", Table.from_rows(
        [{"name": f"warm {i}"} for i in range(2)]))
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                compute_dtype="float32")
    eng = InferenceEngine(cfg, max_len=512, seed=0, kv_layout=layout,
                          page_size=64)
    engines[layout] = eng

    def factory(entry):
        ex = JaxExecutor(eng)
        ex.configure(dict(entry.options))
        return ex

    db.register_executor("bench_jax", factory)
    db.sql("CREATE LLM MODEL anno PATH 'custom:bench_jax' ON PROMPT "
           "OPTIONS { 'batch_size': 1, 'max_str': 8, 'temperature': 0.0, "
           "'num_slots': 8, 'max_tokens': 64 }")
    db.set_option("batch_size", 1)
    # two dispatch batches per query: the second's prefix prefill must be
    # answered by the memo (dense) / resident pool pages (paged)
    db.set_option("max_dispatch_calls", max(2, n // 2))
    return db


def run(quick: bool = False):
    n = 8 if quick else 24

    engines: dict = {}
    walls, results = {}, {}
    for layout in ("dense", "paged"):
        db = _db(n, layout, engines)
        # untimed warmup on disjoint rows: pays each layout's jit compiles
        # (different prompt-cache keys, so the timed query still dispatches)
        # and leaves the instruction prefix resident in the memo/pool —
        # the steady state a serving session runs in
        db.sql(QUERY.replace("FROM Items", "FROM WarmItems"))
        t0 = time.time()
        results[layout] = db.sql(QUERY)
        walls[layout] = time.time() - t0
        db.close()

    r_d, r_p = results["dense"], results["paged"]
    if r_d.table.rows() != r_p.table.rows():
        raise AssertionError("paged layout changed decoded rows")
    pf_d, pf_p = r_d.stats.prefill_tokens, r_p.stats.prefill_tokens
    if not pf_p < pf_d:
        raise AssertionError(
            f"paged prefill tokens not lower: {pf_p} vs dense {pf_d}")
    kv_d = engines["dense"].total.kv_bytes
    kv_p = engines["paged"].total.kv_bytes
    if not kv_p < kv_d:
        raise AssertionError(
            f"paged peak KV bytes not lower: {kv_p} vs dense {kv_d}")
    if r_p.stats.prefix_hits < 1:
        raise AssertionError("paged run never hit the prefix-page memo")

    rows = []
    for layout, r in (("dense", r_d), ("paged", r_p)):
        s = r.stats
        kv = engines[layout].total.kv_bytes
        rows.append((
            f"prefix_paging.{layout}",
            round(walls[layout] / max(1, s.llm_calls) * 1e6, 1),
            f"wall_s={walls[layout]:.2f};prefill_tokens={s.prefill_tokens};"
            f"decode_tokens={s.decode_tokens};peak_kv_bytes={kv};"
            f"prefix_hits={s.prefix_hits};calls={s.llm_calls}"))
    rows.append(("prefix_paging.savings",
                 round((walls["dense"] - walls["paged"]) * 1e6, 1),
                 f"prefill_ratio={pf_d / max(1, pf_p):.2f};"
                 f"kv_ratio={kv_d / max(1, kv_p):.2f}"))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
