"""Fault-tolerance benchmark: chaos overhead, breaker load-shedding, and
crash-safe warm-state restarts.

Scenarios (all self-asserting, like bench_adaptive):

  chaos_free / chaos_faulty
      The same query fault-free vs under seeded transient chaos
      (transient_rate=0.25, <30% of first-occurrence calls).  Rows must
      be byte-identical — retries deterministically succeed — so the
      scenario measures the pure retry overhead in extra backend calls.
  outage_breaker
      A full backend outage: every call raises.  The circuit breaker
      sheds the flood after `failure_threshold` consecutive failures
      (count-based probes keep re-checking), the query degrades to NULL
      outputs instead of erroring, and the derived column reports how
      many calls the breaker saved.
  restart_cold / restart_warm
      Cold start vs snapshot-restored start of the same database.  The
      warm engine must serve its first query with ZERO backend calls —
      every answer comes from the restored PromptCache — which is the
      crash-recovery contract for the serving tier.
  radix_cold / radix_warm
      The paged jax engine's radix prefix tree exported to a snapshot
      and restored into a fresh engine: the first generate() on the
      restored engine must hit the tree (radix_hit_tokens > 0) and
      prefill strictly fewer tokens than the cold engine, with
      byte-identical outputs.

Module-level ``COUNTERS`` aggregates injected-fault / retry / breaker
counters for the run; benchmarks/run.py folds it into
BENCH_results.json.
"""
import json
import threading
import time

from repro.core.database import IPDB
from repro.core.executors import CallResult, Predictor
from repro.core.faults import FaultInjector
from repro.relational.table import Table

COUNTERS = {}

QUERY = ("SELECT a, LLM m (PROMPT 'tag {tag VARCHAR} of {{txt}}') "
         "AS t FROM T")


def oracle(instruction, rows):
    out = []
    for r in rows:
        try:
            i = int(str(r.get("txt", "0")).split()[-1])
        except ValueError:
            i = 0
        out.append({"tag": f"t{i % 5}"})
    return out


class BenchPredictor(Predictor):
    """Deterministic per-row fake backend (one prompt per row, so the
    fault injector's per-prompt decisions sample every row)."""
    name = "bench-resilience"
    max_concurrency = 8

    def __init__(self):
        self.options = {}
        self.calls = 0
        self._lock = threading.Lock()

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        answers = oracle(instruction, rows if rows else [{}])
        objs = [{n: a.get(n) for n, _ in schema} for a in answers]
        while len(objs) < num_rows:
            objs.append({n: None for n, _ in schema})
        text = json.dumps(objs[0] if num_rows == 1 else objs[:num_rows])
        return CallResult(text, max(1, len(shared_prefix + prompt) // 4),
                          max(1, len(text) // 4), 0.01, 0.0)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        with self._lock:
            self.calls += len(prompts)
        rows_list = rows_list if rows_list is not None \
            else [None] * len(prompts)
        return [self.complete(p, schema, nr, shared_prefix=shared_prefix,
                              rows=r, instruction=instruction)
                for p, nr, r in zip(prompts, num_rows_list, rows_list)]


def _db(n, *, predictor=None, snapshot_dir=None, **opts):
    db = IPDB(snapshot_dir=snapshot_dir)
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    pred = predictor if predictor is not None else BenchPredictor()
    db.register_executor("res", lambda entry: pred)
    db.sql("CREATE LLM MODEL m PATH 'custom:res' ON PROMPT")
    db.set_option("batch_size", 4)
    db.set_option("enable_pilot", False)
    for k, v in opts.items():
        db.set_option(k, v)
    return db, pred


def _timed(db, query):
    t0 = time.perf_counter()
    res = db.sql(query)
    return res, time.perf_counter() - t0


def _chaos(n, rows_out):
    db_free, _ = _db(n)
    with db_free:
        ref, wall_free = _timed(db_free, QUERY)
    inj = FaultInjector(BenchPredictor(), seed=7, transient_rate=0.25)
    db_chaos, _ = _db(n, predictor=inj)
    with db_chaos:
        got, wall_chaos = _timed(db_chaos, QUERY)
    if got.table.rows() != ref.table.rows():
        raise AssertionError("chaos run diverged from the fault-free run")
    if inj.counters["transient"] == 0:
        raise AssertionError("chaos harness injected no faults")
    if got.stats.transient_retries < inj.counters["transient"]:
        raise AssertionError("injected transients were not all retried")
    COUNTERS["injected_transient"] = inj.counters["transient"]
    COUNTERS["transient_retries"] = got.stats.transient_retries
    for name, r, wall in (("chaos_free", ref, wall_free),
                          ("chaos_faulty", got, wall_chaos)):
        s = r.stats
        rows_out.append((
            f"resilience.{name}",
            round(wall / max(1, s.llm_calls) * 1e6, 1),
            f"calls={s.llm_calls};retries={s.transient_retries};"
            f"rows={len(r.table)};wall_ms={wall * 1e3:.1f}"))


def _outage(n, rows_out):
    inj = FaultInjector(BenchPredictor(), seed=0, outage=(0, 10**9))
    db, _ = _db(n, predictor=inj, retry_limit=1,
                breaker_threshold=3, breaker_probe_every=8)
    with db:
        res, wall = _timed(db, QUERY)
        snap = db.inference_service.breaker_for("m").snapshot()
    if any(r["t"] is not None for r in res.table.rows()):
        raise AssertionError("outage must degrade every answer to NULL")
    if snap["opens"] < 1:
        raise AssertionError("outage never tripped the breaker")
    if res.stats.breaker_rejections == 0:
        raise AssertionError("open breaker shed no calls")
    COUNTERS["breaker_opens"] = snap["opens"]
    COUNTERS["breaker_rejections"] = res.stats.breaker_rejections
    COUNTERS["outage_calls_attempted"] = inj.counters["calls"]
    rows_out.append((
        "resilience.outage_breaker",
        round(wall / max(1, n) * 1e6, 1),
        f"attempted={inj.counters['calls']};"
        f"shed={res.stats.breaker_rejections};opens={snap['opens']};"
        f"rows={len(res.table)};wall_ms={wall * 1e3:.1f}"))


def _restart(n, rows_out):
    import shutil
    import tempfile
    snapdir = tempfile.mkdtemp(prefix="ipdb-bench-snap-")
    try:
        cold_inj = FaultInjector(BenchPredictor(), seed=0)
        db_cold, _ = _db(n, predictor=cold_inj, snapshot_dir=snapdir)
        with db_cold:
            ref, wall_cold = _timed(db_cold, QUERY)
            db_cold.save_snapshot()
        warm_inj = FaultInjector(BenchPredictor(), seed=0)
        db_warm, _ = _db(n, predictor=warm_inj, snapshot_dir=snapdir)
        if db_warm.restored_snapshot is None:
            raise AssertionError("restart did not restore the snapshot")
        with db_warm:
            got, wall_warm = _timed(db_warm, QUERY)
        if warm_inj.counters["calls"] != 0:
            raise AssertionError(
                f"warm restart made {warm_inj.counters['calls']} backend "
                f"calls — expected 0 (all answers from the PromptCache)")
        if got.stats.prompt_cache_hits != n:
            raise AssertionError("warm restart missed the prompt cache")
        if got.table.rows() != ref.table.rows():
            raise AssertionError("warm restart changed the rows")
        COUNTERS["warm_restart_backend_calls"] = warm_inj.counters["calls"]
        COUNTERS["warm_restart_cache_hits"] = got.stats.prompt_cache_hits
        for name, r, wall, calls in (
                ("restart_cold", ref, wall_cold, cold_inj.counters["calls"]),
                ("restart_warm", got, wall_warm, warm_inj.counters["calls"])):
            rows_out.append((
                f"resilience.{name}",
                round(wall / max(1, n) * 1e6, 1),
                f"backend_calls={calls};"
                f"cache_hits={r.stats.prompt_cache_hits};"
                f"rows={len(r.table)};wall_ms={wall * 1e3:.1f}"))
    finally:
        shutil.rmtree(snapdir, ignore_errors=True)


def _radix_restart(quick, rows_out):
    import repro.configs as C
    from repro.serving.engine import InferenceEngine
    from repro.serving.grammar import Field, JsonGrammar

    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                compute_dtype="float32")
    mk = lambda: InferenceEngine(cfg, seed=0, max_len=512,  # noqa: E731
                                 kv_layout="paged", page_size=32)
    prefix = ("SHARED INSTRUCTION BLOCK: extract the field from the row. "
              * 3)
    g = JsonGrammar([Field("x", "INTEGER")])
    n = 3 if quick else 6
    prompts = [f"row {i}: value {i * 7}" for i in range(n)]
    cold = mk()
    t0 = time.perf_counter()
    r_cold = cold.generate(prompts, grammar=g, shared_prefix=prefix,
                           max_new_tokens=24)
    wall_cold = time.perf_counter() - t0
    state = cold.export_radix_state()
    if not state or not state["entries"]:
        raise AssertionError("radix export produced no pages")
    warm = mk()
    restored = warm.restore_radix_state(state)
    if restored == 0:
        raise AssertionError("radix restore adopted no pages")
    t0 = time.perf_counter()
    r_warm = warm.generate(prompts, grammar=g, shared_prefix=prefix,
                           max_new_tokens=24)
    wall_warm = time.perf_counter() - t0
    if r_warm.texts != r_cold.texts:
        raise AssertionError("radix-restored engine changed outputs")
    if r_warm.stats.radix_hit_tokens == 0:
        raise AssertionError("restored radix tree served no tokens")
    if r_warm.stats.prefill_tokens >= r_cold.stats.prefill_tokens:
        raise AssertionError("restored radix tree saved no prefill")
    COUNTERS["radix_restored_pages"] = restored
    COUNTERS["radix_warm_hit_tokens"] = r_warm.stats.radix_hit_tokens
    for name, r, wall in (("radix_cold", r_cold, wall_cold),
                          ("radix_warm", r_warm, wall_warm)):
        rows_out.append((
            f"resilience.{name}",
            round(wall / max(1, n) * 1e6, 1),
            f"prefill_tokens={r.stats.prefill_tokens};"
            f"radix_hit_tokens={r.stats.radix_hit_tokens};"
            f"wall_ms={wall * 1e3:.1f}"))


def run(quick: bool = False):
    COUNTERS.clear()
    n = 48 if quick else 160
    rows = []
    _chaos(n, rows)
    _outage(24 if quick else 80, rows)
    _restart(n, rows)
    _radix_restart(quick, rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(",".join(str(x) for x in r))
    print("#", COUNTERS)
