"""Baseline-system emulations (paper §7 B1–B5).

Each baseline = an iPDB instance configured to the competitor's documented
execution strategy, so the SAME queries/oracles/latency-model isolate the
systems differences the paper measures:

  LOTUS  (B1) — per-tuple calls, 16-way parallel, no dedup/marshaling, no
                logical optimization; re-sends system+format instructions
                per call; a model refusal aborts the whole pipeline.
  EvaDB  (B2) — scalar functions only (no table inference / semantic join),
                per-tuple sequential-ish (4 workers), adaptive predicate
                routing only.
  Flock  (B3) — value-concatenation batching (64-row chunks) WITHOUT
                structured extraction: unstructured responses, no retry →
                frequent parse losses (low F1), few calls.
  BigQuery(B4)— scalable parallel backend, no row marshaling, no semantic
                predicate ordering (processes the full join input).
  iPDB   (B5) — everything on.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, Optional

from repro.core.database import IPDB
from repro.core.executors import CallResult, OracleExecutor
from repro.serving import tokenizer as TOK


class UnstructuredOracleExecutor(OracleExecutor):
    """Flock-style: answers concatenated as plain text — the predict parser
    usually fails, modelling the paper's 'results are not structured' F1
    collapse, while calls/tokens stay batched-low."""

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        res = super().complete(prompt, schema, num_rows,
                               shared_prefix=shared_prefix, rows=rows,
                               instruction=instruction)
        try:
            v = json.loads(res.text)
        except json.JSONDecodeError:
            return res
        objs = v if isinstance(v, list) else [v]
        flat = "; ".join(" ".join(str(x) for x in o.values()) for o in objs)
        text = f"The answers are: {flat}"
        return CallResult(text, res.in_tokens, TOK.count_tokens(text),
                          res.sim_latency_s, res.wall_s)


class RefusalAbort(RuntimeError):
    pass


class AbortOnRefusalExecutor(OracleExecutor):
    """LOTUS-style: a single refused tuple fails the entire pipeline
    (paper §7.3 Q1 failure mode)."""

    def complete(self, *a, **kw):
        res = super().complete(*a, **kw)
        if res.text.startswith("I cannot help"):
            raise RefusalAbort("model refused; pipeline aborted")
        return res


@dataclasses.dataclass
class SystemSpec:
    name: str
    options: Dict[str, object]
    executor_cls: type = OracleExecutor
    supports: tuple = ("project", "select", "join", "generate", "agg",
                       "table_inference")


SYSTEMS: Dict[str, SystemSpec] = {
    "LOTUS": SystemSpec(
        name="LOTUS",
        options={"use_dedup": False, "use_batching": False, "n_threads": 16,
                 "enable_pullup": False, "enable_join_order": False,
                 "enable_merge": False, "enable_select_order": False},
        executor_cls=AbortOnRefusalExecutor,
        supports=("project", "select", "join", "agg", "table_inference")),
    "EvaDB": SystemSpec(
        name="EvaDB",
        options={"use_dedup": False, "use_batching": False, "n_threads": 4,
                 "enable_pullup": False, "enable_join_order": False,
                 "enable_merge": False, "enable_select_order": False},
        supports=("project", "select")),
    "Flock": SystemSpec(
        name="Flock",
        options={"use_dedup": False, "use_batching": True, "batch_size": 64,
                 "n_threads": 16, "retry_limit": 0,
                 "enable_pullup": False, "enable_join_order": False,
                 "enable_merge": False, "enable_select_order": False},
        executor_cls=UnstructuredOracleExecutor,
        supports=("project", "select", "agg")),
    "BigQuery": SystemSpec(
        name="BigQuery",
        options={"use_dedup": False, "use_batching": False, "n_threads": 64,
                 "enable_pullup": False, "enable_join_order": False,
                 "enable_merge": False, "enable_select_order": True},
        supports=("project", "select", "join", "agg", "table_inference")),
    "iPDB": SystemSpec(
        name="iPDB",
        options={"use_dedup": True, "use_batching": True, "batch_size": 16,
                 "n_threads": 16, "enable_pullup": True,
                 "enable_join_order": True, "enable_merge": True,
                 "enable_select_order": True}),
}


def make_db(system: str, tables, oracle, *, error_rate=0.02,
            malform_rate=0.01, refusal_rate=0.0, seed=0,
            extra_options: Optional[dict] = None) -> IPDB:
    spec = SYSTEMS[system]
    db = IPDB()
    for name, t in tables.items():
        db.register_table(name, t)
    for k, v in spec.options.items():
        db.set_option(k, v)
    for k, v in (extra_options or {}).items():
        db.set_option(k, v)

    def factory(fn=oracle, **kw):
        return spec.executor_cls(fn, error_rate=error_rate,
                                 malform_rate=malform_rate,
                                 refusal_rate=refusal_rate, seed=seed)

    db._oracles["bench"] = oracle
    db._oracle_kwargs["bench"] = {}
    # monkey-wire the executor class through the normal resolution path
    orig = db._make_executor

    def _mk(entry):
        if entry.path == "oracle:bench":
            return factory()
        return orig(entry)

    db._make_executor = _mk
    db.sql("CREATE LLM MODEL m PATH 'oracle:bench' ON PROMPT "
           "API 'https://api.openai.com/v1/'")
    return db


def f1_score(pred, gold) -> float:
    """Binary/row-set F1 over aligned lists (None counts as wrong)."""
    tp = sum(1 for p, g in zip(pred, gold) if p is not None and p == g and g)
    fp = sum(1 for p, g in zip(pred, gold) if p and p != g)
    fn = sum(1 for p, g in zip(pred, gold) if g and p != g)
    if tp == 0:
        return 0.0
    prec = tp / (tp + fp)
    rec = tp / (tp + fn)
    return 2 * prec * rec / (prec + rec)


def accuracy_f1(pred, gold) -> float:
    """Macro-F1 over label values for multi-class string predictions."""
    labels = set(g for g in gold)
    f1s = []
    for lab in labels:
        tp = sum(1 for p, g in zip(pred, gold) if p == lab and g == lab)
        fp = sum(1 for p, g in zip(pred, gold) if p == lab and g != lab)
        fn = sum(1 for p, g in zip(pred, gold) if p != lab and g == lab)
        if tp == 0:
            f1s.append(0.0)
            continue
        prec, rec = tp / (tp + fp), tp / (tp + fn)
        f1s.append(2 * prec * rec / (prec + rec))
    return sum(f1s) / max(1, len(f1s))
