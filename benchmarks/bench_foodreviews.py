"""Table 6: FoodReviews (D2) — single semantic select, intra-operator
optimizations only (dedup + marshaling + parallelization)."""
from benchmarks.datasets import make_foodreviews
from benchmarks.systems import SYSTEMS, accuracy_f1, make_db

Q = ("SELECT rid, LLM m (PROMPT 'is this {{review}} about food or service? "
     "{topic VARCHAR}') AS topic FROM FoodReview")


def run(quick: bool = False):
    tables, oracle, gt = make_foodreviews(n=220 if quick else 1014)
    gold = {r["rid"]: r["label_gt"] for r in gt}
    rows = []
    for sysname in ("LOTUS", "EvaDB", "Flock", "iPDB"):
        db = make_db(sysname, tables, oracle, error_rate=0.03,
                     malform_rate=0.01)
        res = db.sql(Q)
        pred = {r["rid"]: r["topic"] for r in res.table.rows()}
        f1 = accuracy_f1([pred.get(k) for k in gold], list(gold.values()))
        s = res.stats
        rows.append((f"foodreviews.{sysname}",
                     round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
                     f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                     f"tokens={s.tokens};f1={f1:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
