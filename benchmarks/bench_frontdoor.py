"""Front-door saturation benchmark: tail latency under multi-tenant load.

Workload (seeded, open-loop): a heavy tenant keeps the shared dispatch
pool saturated with a backlog of long streaming queries (3 closed-loop
sessions issuing back-to-back), while a light tenant's short queries
arrive on a seeded exponential (Poisson-ish) schedule and their
end-to-end latency (arrival -> trailer) is measured.  Every query uses a
unique instruction so the cross-query prompt cache never answers — each
chunk costs real dispatch work (a scripted backend with a fixed
per-call sleep).

Two passes over the identical schedule, fresh database each:

  fifo   chunk slots granted in pure arrival order — the light tenant
         queues behind every heavy session's next chunk
  drr    the deficit-round-robin credit gate (fairness.py) — heavy
         chunk costs drive that tenant's credit negative, so light
         waiters win the next slot

plus a saturation mini-pass (max_sessions=1, max_queued=0) counting
admission rejections (429) and a mid-stream client abort (cancelled
session).  Acceptance (asserted): DRR bounds the light tenant's p99
below 0.9x FIFO's, and the mini-pass actually rejects and cancels.
"""
import random
import threading
import time

from repro.core.database import IPDB
from repro.frontdoor import (DeficitRoundRobin, FifoGate, FrontDoor,
                             FrontDoorClient, QueryRejected)
from repro.relational.table import Table

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
from helpers import LatencyScriptedPredictor, register_scripted  # noqa: E402


def _answers(instruction, rows):
    return [{"tag": f"t{sum(map(ord, str(sorted(r.items())))) % 5}"}
            for r in rows]


def _mk_db(n, sleep_s):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    pred = LatencyScriptedPredictor(_answers, base_latency_s=0.05,
                                    sleep_per_call_s=sleep_s)
    register_scripted(db, "m", pred)
    db.set_option("chunk_size", 8)
    db.set_option("batch_size", 8)
    db.set_option("enable_pilot", False)
    return db


def _q(uid, limit=None):
    tail = f" LIMIT {limit}" if limit else ""
    return ("SELECT a, LLM m (PROMPT 'q" + str(uid) +
            " {tag VARCHAR} of {{txt}}') AS t FROM T" + tail)


def _percentile(xs, p):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(p * (len(xs) - 1))))]


def _load_pass(gate, *, n_rows, sleep_s, n_light, mean_gap_s, seed):
    """One measured pass: returns (light latencies, gate grant counts)."""
    db = _mk_db(n_rows, sleep_s)
    uid = [0]

    def next_uid():
        uid[0] += 1
        return uid[0]

    lat = []
    lat_lock = threading.Lock()
    stop = threading.Event()
    with db, FrontDoor(db, max_sessions=6, max_queued=64,
                       gate=gate) as fd:
        cli = FrontDoorClient(fd.host, fd.port)

        def heavy_loop():
            while not stop.is_set():
                try:
                    cli.query(_q(next_uid()), tenant="heavy").result()
                except (QueryRejected, ConnectionError, OSError):
                    time.sleep(0.01)

        heavies = [threading.Thread(target=heavy_loop, daemon=True)
                   for _ in range(3)]
        for t in heavies:
            t.start()
        time.sleep(0.15)                       # build the heavy backlog

        rng = random.Random(seed)
        gaps = [rng.expovariate(1.0 / mean_gap_s) for _ in range(n_light)]

        def light_once():
            t0 = time.time()
            try:
                cli.query(_q(next_uid(), limit=8),
                          tenant="light").result()
            except (QueryRejected, ConnectionError, OSError):
                return
            with lat_lock:
                lat.append(time.time() - t0)

        probes = []
        for gap in gaps:                       # open loop: fixed schedule
            time.sleep(gap)
            t = threading.Thread(target=light_once, daemon=True)
            t.start()
            probes.append(t)
        for t in probes:
            t.join(timeout=30)
        stop.set()
        for t in heavies:
            t.join(timeout=30)
        grants = dict(fd.gate.grants)
    return lat, grants


def _saturation_pass():
    """Admission + cancellation counters under a hard session cap."""
    release = threading.Event()

    def hold(pred, prompts):
        release.wait(timeout=10)

    db = _mk_db(64, 0.0)
    # a second, gated model so the running session pins its worker until
    # released
    pred = LatencyScriptedPredictor(_answers, gate=hold)
    register_scripted(db, "g", pred)
    sql = ("SELECT a, LLM g (PROMPT 'sat {tag VARCHAR} of {{txt}}') "
           "AS t FROM T")
    rejected = 0
    with db, FrontDoor(db, max_sessions=1, max_queued=0) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        running = cli.query(sql, tenant="heavy")
        deadline = time.time() + 5
        while fd._active < 1 and time.time() < deadline:
            time.sleep(0.01)
        for _ in range(4):
            try:
                cli.query(sql, tenant="light")
            except QueryRejected:
                rejected += 1
        running.abort()                        # mid-stream client abort
        deadline = time.time() + 10
        while (fd.counters.get("cancelled_sessions", 0) == 0
               and time.time() < deadline):   # let the EOF watch fire
            time.sleep(0.01)                  # before releasing the gate
        release.set()
        deadline = time.time() + 5
        while fd._sessions and time.time() < deadline:
            time.sleep(0.02)
        stats = cli.server_stats()
    return rejected, stats.get("cancelled_sessions", 0)


def run(quick: bool = False):
    n_rows = 64 if quick else 128
    n_light = 10 if quick else 30
    sleep_s = 0.01
    mean_gap_s = 0.05
    seed = 17

    results = {}
    for label, gate in (("fifo", FifoGate(1)), ("drr",
                                                DeficitRoundRobin(1))):
        lat, grants = _load_pass(gate, n_rows=n_rows, sleep_s=sleep_s,
                                 n_light=n_light, mean_gap_s=mean_gap_s,
                                 seed=seed)
        if not lat:
            raise AssertionError(f"{label}: no light queries completed")
        results[label] = {
            "p50": _percentile(lat, 0.50), "p99": _percentile(lat, 0.99),
            "n": len(lat),
            "light_share": grants.get("light", 0)
            / max(1, sum(grants.values())),
        }

    rejected, cancelled = _saturation_pass()
    if rejected == 0:
        raise AssertionError("saturation pass never hit admission control")
    if cancelled == 0:
        raise AssertionError("client abort did not cancel the session")

    drr, fifo = results["drr"], results["fifo"]
    if drr["p99"] >= 0.9 * fifo["p99"]:
        raise AssertionError(
            "DRR failed to bound the light tenant's tail: p99 "
            f"{drr['p99'] * 1e3:.1f}ms (drr) vs {fifo['p99'] * 1e3:.1f}ms "
            "(fifo) — expected < 0.9x")

    rows = []
    for label in ("fifo", "drr"):
        r = results[label]
        rows.append((
            f"frontdoor.{label}",
            round(r["p99"] * 1e6, 1),          # light-tenant p99 in us
            f"light_p50_ms={r['p50'] * 1e3:.1f};"
            f"light_p99_ms={r['p99'] * 1e3:.1f};"
            f"light_n={r['n']};light_slot_share={r['light_share']:.3f}"))
    rows.append((
        "frontdoor.saturation", 0.0,
        f"rejected_429={rejected};cancelled_sessions={cancelled};"
        f"p99_ratio_drr_over_fifo={drr['p99'] / fifo['p99']:.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run(quick=True):
        print(f"{name},{us},{derived}")
