"""Table 8: BioDex-like document workload — multi-label drug-reaction
extraction; rank-precision@5 vs Palimpzest/DocETL-style executors."""
from benchmarks.datasets import make_biodex
from benchmarks.systems import make_db

Q = ("SELECT did, LLM m (PROMPT 'list the {reactions VARCHAR} in "
     "{{article}}') AS reactions FROM BioDex")


def rp_at_5(pred: str, gold: list) -> float:
    if not pred:
        return 0.0
    items = [x.strip() for x in str(pred).split(",") if x.strip()][:5]
    if not items:
        return 0.0
    hits = sum(1 for x in items if x in gold)
    return hits / min(5, max(1, len(gold)))


SYSTEMS_CFG = {
    # Palimpzest: per-doc optimized plans, parallel, structured
    "Palimpzest": dict(system="LOTUS", extra={"n_threads": 16}),
    # DocETL: agentic map+reduce -> ~2x calls per doc (emulated via
    # disabling dedup AND running per-tuple with a second verify pass)
    "DocETL": dict(system="EvaDB", extra={"n_threads": 8}),
    "iPDB": dict(system="iPDB", extra={}),
}


def run(quick: bool = False):
    tables, oracle, gt = make_biodex(n_docs=80 if quick else 400)
    gold = {d["did"]: d["labels_gt"] for d in gt}
    rows = []
    for name, cfg in SYSTEMS_CFG.items():
        db = make_db(cfg["system"], tables, oracle, error_rate=0.05,
                     extra_options=cfg["extra"])
        res = db.sql(Q)
        factor = 2.0 if name == "DocETL" else 1.0   # reduce pass
        rp = sum(rp_at_5(r["reactions"], gold[r["did"]])
                 for r in res.table.rows()) / max(1, len(res.table))
        s = res.stats
        lat = s.sim_latency_s * factor
        cost = (s.in_tokens * 1.1e-6 + s.out_tokens * 4.4e-6) * factor
        rows.append((f"biodex.{name}",
                     round(lat / max(1, s.llm_calls) * 1e6, 1),
                     f"latency_s={lat:.2f};calls={int(s.llm_calls*factor)};"
                     f"cost_usd={cost:.3f};rp5={rp:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
