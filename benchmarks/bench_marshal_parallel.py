"""Figure 5: row-marshaling vs parallelization under a provider rate limit
(500 rpm, 10k tuples) — the marshal batch size breaks through the
parallelism ceiling."""
from repro.core.executors import default_latency_model
from repro.core.predict import makespan


def run(quick: bool = False):
    n_tuples = 10_000
    rpm = 500.0
    rows = []
    for bs in (1, 4, 8, 16, 32):
        n_calls = (n_tuples + bs - 1) // bs
        lat = default_latency_model(60 + 40 * bs, 18 * bs)
        for workers in (1, 8, 16, 32, 48, 64, 96):
            total = makespan([lat] * n_calls, workers, rpm=rpm)
            rows.append((
                f"marshal_parallel.bs{bs}.w{workers}",
                round(total / n_calls * 1e6, 1),
                f"latency_s={total:.1f};calls={n_calls};"
                f"per_call_s={lat:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
