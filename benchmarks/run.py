"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set IPDB_BENCH_QUICK=1 for the
reduced-size pass (used by CI/test_output runs); the full pass reproduces
the paper-scale ratios.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

MODULES = [
    ("pcparts_T5", "benchmarks.bench_pcparts"),
    ("foodreviews_T6", "benchmarks.bench_foodreviews"),
    ("semanticmovies_T7", "benchmarks.bench_semanticmovies"),
    ("biodex_T8", "benchmarks.bench_biodex"),
    ("intraop_F3", "benchmarks.bench_intraop"),
    ("batchsize_F4", "benchmarks.bench_batchsize"),
    ("marshal_parallel_F5", "benchmarks.bench_marshal_parallel"),
    ("pullup_F6", "benchmarks.bench_pullup"),
    ("join_ordering_F7", "benchmarks.bench_join_ordering"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    import importlib
    quick = os.environ.get("IPDB_BENCH_QUICK", "0") == "1"
    print("name,us_per_call,derived")
    failures = 0
    for label, modname in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(quick=quick)
            for name, us, derived in rows:
                print(f"{name},{us},{derived}", flush=True)
            print(f"# {label} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"{label}.ERROR,,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
