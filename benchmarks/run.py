"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a machine-readable
``BENCH_results.json`` (name → us_per_call/derived, plus quick-mode flag
and git SHA); every run is ALSO appended as one JSON line (keyed by git
SHA + timestamp) to ``BENCH_trajectory.jsonl``, so the perf trajectory
across PRs accumulates instead of being overwritten.  Set
IPDB_BENCH_QUICK=1 for the reduced-size pass (used by CI/test_output
runs); the full pass reproduces the paper-scale ratios.  ``--only``
filters modules by label substring (comma-separated); ``--trajectory``
overrides the jsonl path ('' disables).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback

MODULES = [
    ("pcparts_T5", "benchmarks.bench_pcparts"),
    ("foodreviews_T6", "benchmarks.bench_foodreviews"),
    ("semanticmovies_T7", "benchmarks.bench_semanticmovies"),
    ("biodex_T8", "benchmarks.bench_biodex"),
    ("intraop_F3", "benchmarks.bench_intraop"),
    ("batchsize_F4", "benchmarks.bench_batchsize"),
    ("marshal_parallel_F5", "benchmarks.bench_marshal_parallel"),
    ("pullup_F6", "benchmarks.bench_pullup"),
    ("join_ordering_F7", "benchmarks.bench_join_ordering"),
    ("adaptive_stats", "benchmarks.bench_adaptive"),
    ("multibackend", "benchmarks.bench_multibackend"),
    ("prefix_paging", "benchmarks.bench_prefix_paging"),
    ("cascade", "benchmarks.bench_cascade"),
    ("frontdoor", "benchmarks.bench_frontdoor"),
    ("rewrite", "benchmarks.bench_rewrite"),
    ("resilience", "benchmarks.bench_resilience"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.roofline"),
]


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    import importlib
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated label substrings to run")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="path for the machine-readable results "
                         "('' disables)")
    ap.add_argument("--trajectory", default="BENCH_trajectory.jsonl",
                    help="append-only per-run results log "
                         "('' disables)")
    args = ap.parse_args(argv)
    quick = os.environ.get("IPDB_BENCH_QUICK", "0") == "1"
    wanted = [w for w in args.only.split(",") if w]
    unmatched = [w for w in wanted
                 if not any(w in label for label, _ in MODULES)]
    if unmatched:
        sys.exit(f"--only tokens match no benchmark module: {unmatched} "
                 f"(labels: {[label for label, _ in MODULES]})")
    modules = [m for m in MODULES
               if not wanted or any(w in m[0] for w in wanted)]
    print("name,us_per_call,derived")
    results = {}
    counters = {}
    failures = 0
    for label, modname in modules:
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(quick=quick)
            for name, us, derived in rows:
                print(f"{name},{us},{derived}", flush=True)
                results[name] = {"us_per_call": us, "derived": derived}
            mod_counters = getattr(mod, "COUNTERS", None)
            if mod_counters:
                counters[label] = dict(mod_counters)
            print(f"# {label} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"{label}.ERROR,,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    record = {"quick": quick, "git_sha": _git_sha(),
              "failures": failures, "results": results,
              "counters": counters}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json} ({len(results)} results)", flush=True)
    if args.trajectory and results:
        with open(args.trajectory, "a") as f:
            f.write(json.dumps({"ts": round(time.time(), 1),
                                "only": args.only, **record},
                               sort_keys=True) + "\n")
        print(f"# appended to {args.trajectory}", flush=True)
    if not results:
        sys.exit("benchmarks produced no output")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
