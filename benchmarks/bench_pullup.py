"""Figure 6: predict pull-up on/off (D1:Q4-style semantic select behind a
traditional filter + join)."""
from benchmarks.datasets import make_pcparts
from benchmarks.systems import make_db

Q = ("SELECT review FROM Product AS p NATURAL JOIN Review AS r WHERE "
     "LLM m (PROMPT 'is the sentiment of {{review}} {negative BOOLEAN}') "
     "= TRUE AND category = 'CPU'")


def run(quick: bool = False):
    tables, oracle, _ = make_pcparts(n_products=60 if quick else 220,
                                     n_reviews=200 if quick else 950)
    rows = []
    # dedup/marshaling off to isolate the logical rule (paper Fig. 6
    # reports calls/tokens/latency of the pull-up alone)
    base = {"use_dedup": False, "use_batching": False}
    for name, flags in (("pullup_on", {"enable_pullup": True}),
                        ("pullup_off", {"enable_pullup": False})):
        db = make_db("iPDB", tables, oracle,
                     extra_options={**base, **flags})
        res = db.sql(Q)
        s = res.stats
        rows.append((f"pullup.{name}",
                     round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
                     f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                     f"tokens={s.tokens};rows_pred={s.rows_predicted}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
