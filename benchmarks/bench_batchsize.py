"""Figure 4: per-call generation latency vs row-marshaled batch size —
measured on the REAL JAX engine (grammar-constrained decode of N marshaled
rows), plus the oracle latency model for the remote analog."""
import time

from repro.core.executors import default_latency_model


def run(quick: bool = False):
    rows = []
    # simulated remote model (paper's o4-mini curve shape)
    for bs in (1, 2, 4, 8, 16, 32, 64):
        in_t = 60 + 40 * bs          # instruction + bs rows
        out_t = 18 * bs
        lat = default_latency_model(in_t, out_t)
        rows.append((f"batchsize.remote.bs{bs}", round(lat * 1e6, 1),
                     f"latency_s={lat:.3f};in_tokens={in_t};out_tokens={out_t}"))
    # real JAX engine
    import repro.configs as C
    from repro.serving.engine import InferenceEngine
    from repro.serving.grammar import Field, JsonGrammar
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259)
    eng = InferenceEngine(cfg, max_len=2048)
    sizes = (1, 2, 4) if quick else (1, 2, 4, 8, 16)
    for bs in sizes:
        g = JsonGrammar([Field("topic", "VARCHAR")], num_rows=bs, max_str=6)
        prompt = "classify rows: " + "; ".join(f"row {i} text" for i in range(bs))
        eng.generate([prompt], grammar=g, max_new_tokens=40 * bs)  # warmup
        t0 = time.time()
        res = eng.generate([prompt], grammar=g, max_new_tokens=40 * bs)
        dt = time.time() - t0
        rows.append((f"batchsize.jax_engine.bs{bs}", round(dt * 1e6, 1),
                     f"latency_s={dt:.3f};decode_steps={res.stats.decode_steps};"
                     f"prefill_tokens={res.stats.prefill_tokens}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
