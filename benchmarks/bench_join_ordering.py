"""Figure 7: semantic select vs join ordering across the PK/FK matrix
(paper §7.9): select on the FK side, PK side (1:N), and many-to-many."""
import numpy as np

from repro.core.database import IPDB
from repro.relational.table import Table
from benchmarks.systems import SYSTEMS, make_db


def _mk(seed, n_pk=60, n_fk=600):
    rng = np.random.default_rng(seed)
    pk = [{"pid": i, "pdesc": f"alpha text {i} " + "x" * 40}
          for i in range(n_pk)]
    # one third of PK rows have no FK partner (join eliminates them)
    fk = [{"fid": i, "pid": int(rng.integers(0, max(1, 2 * n_pk // 3))),
           "fdesc": f"beta text {i % 50}"} for i in range(n_fk)]
    return pk, fk


def oracle(instruction, rows):
    out = []
    for r in rows:
        v = " ".join(str(x) for x in r.values())
        out.append({"flag": v.endswith(("1", "3", "5", "7"))})
    return out


def run(quick: bool = False):
    n_pk, n_fk = (20, 120) if quick else (60, 600)
    pk, fk = _mk(0, n_pk, n_fk)
    rows = []
    cases = {
        # select predicate reads the FK side column
        "fk_side": ("SELECT fid FROM P JOIN F ON pid = pid WHERE "
                    "LLM m (PROMPT 'check {flag BOOLEAN} of {{fdesc}}') = TRUE"),
        # select predicate reads the PK side column (1:N duplication)
        "pk_side": ("SELECT fid FROM P JOIN F ON pid = pid WHERE "
                    "LLM m (PROMPT 'check {flag BOOLEAN} of {{pdesc}}') = TRUE"),
    }
    for case, q in cases.items():
        for name, flags in (("optimized", {}),
                            ("push_naive", {"enable_join_order": False,
                                            "use_dedup": False})):
            db = IPDB()
            db.register_table("P", Table.from_rows(pk))
            db.register_table("F", Table.from_rows(fk))
            db.register_oracle("bench", oracle)
            for k, v in SYSTEMS["iPDB"].options.items():
                db.set_option(k, v)
            for k, v in flags.items():
                db.set_option(k, v)
            db.set_option("use_batching", False)
            db.sql("CREATE LLM MODEL m PATH 'oracle:bench' ON PROMPT")
            res = db.sql(q)
            s = res.stats
            rows.append((f"join_order.{case}.{name}",
                         round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
                         f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                         f"tokens={s.tokens}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
