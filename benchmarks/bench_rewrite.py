"""Learned-rewrite + mid-query re-optimization benchmark.

Two workloads, each compared against the same engine with the new
machinery switched off, with byte-identical result rows asserted:

  duplicate subexpression   the same LLM predicate appears in the WHERE
                            clause and the SELECT list.  The rewrite
                            engine's consolidation pattern aliases the
                            SELECT-list predict onto the WHERE predict's
                            answers, so the model runs once per row
                            instead of twice (in-flight dedup is OFF to
                            show the plan-level win on its own).

  selectivity drift         two commuting semantic selects whose pass
                            rates INVERT halfway through the table: the
                            predicate that filters everything early
                            passes everything late.  Any static order is
                            stale for half the stream; the
                            SemanticSelectStackOp re-ranks on observed
                            chunk selectivities and pays fewer calls and
                            less modeled makespan than the frozen order.

The run raises AssertionError when consolidation does not strictly
reduce calls, when the re-ranked drift run does not strictly beat the
static order on calls AND modeled makespan, or when any rows differ.
"""
from repro.core.database import IPDB
from repro.relational.table import Table


# -- workload 1: duplicate semantic subexpression ---------------------------
def _dup_oracle(instruction, rows):
    out = []
    for r in rows:
        i = int(str(r.get("txt", "doc 0")).split()[-1])
        out.append({"score": i % 10})
    return out


DUP_QUERY = ("SELECT rid, LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') "
             "AS s FROM R WHERE "
             "LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') > 4")


def _dup_db(n, rewrites):
    db = IPDB()
    db.register_table("R", Table.from_rows(
        [{"rid": i, "txt": f"doc {i}"} for i in range(n)]))
    db.register_oracle("bench", _dup_oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:bench' ON PROMPT")
    db.set_option("use_batching", False)     # per-row calls: clean counts
    db.set_option("use_dedup", False)        # isolate the plan-level win
    db.set_option("enable_pilot", False)
    db.set_option("enable_rewrites", rewrites)
    return db


# -- workload 2: selectivity drift ------------------------------------------
def _drift_oracle(n):
    def orc(instruction, rows):
        out = []
        for r in rows:
            i = int(str(r.get("txt", "doc 0")).split()[-1])
            if '"early"' in instruction:
                # passes almost nothing in the first half, everything after
                out.append({"early": i >= n // 2 or i % 10 == 0})
            else:
                out.append({"late": i < n // 2 or i % 7 == 0})
        return out
    return orc


DRIFT_QUERY = ("SELECT rid FROM R WHERE "
               "LLM m (PROMPT 'check {early BOOLEAN} of {{txt}}') = TRUE "
               "AND LLM m (PROMPT 'check {late BOOLEAN} of {{txt}}') = TRUE")


def _drift_db(n, reopt):
    db = IPDB()
    db.register_table("R", Table.from_rows(
        [{"rid": i, "txt": f"doc {i}"} for i in range(n)]))
    db.register_oracle("bench", _drift_oracle(n))
    db.sql("CREATE LLM MODEL m PATH 'oracle:bench' ON PROMPT")
    db.set_option("use_batching", False)
    db.set_option("enable_pilot", False)
    db.set_option("chunk_size", max(10, n // 8))
    # few dispatch threads: per-chunk call counts exceed the pool, so
    # saved calls shorten the modeled makespan instead of hiding inside
    # one parallel wave
    db.set_option("n_threads", 4)
    db.set_option("enable_reopt", reopt)
    return db


def _assert_same_rows(name, r1, r2, key="rid"):
    if list(r1.table.column(key)) != list(r2.table.column(key)):
        raise AssertionError(f"{name}: result rows differ")


def run(quick: bool = False):
    n = 120 if quick else 360

    # duplicate subexpression: rewrites on vs off
    r_on = _dup_db(n, rewrites=True).sql(DUP_QUERY, explain=True)
    r_off = _dup_db(n, rewrites=False).sql(DUP_QUERY)
    _assert_same_rows("consolidation", r_on, r_off)
    if list(r_on.table.column("s")) != list(r_off.table.column("s")):
        raise AssertionError("consolidation: predicted column differs")
    if r_on.stats.llm_calls >= r_off.stats.llm_calls:
        raise AssertionError(
            f"consolidation made {r_on.stats.llm_calls} calls vs "
            f"{r_off.stats.llm_calls} static — expected a strict reduction")
    if "consolidate_duplicate_predicts" not in (r_on.plan or ""):
        raise AssertionError("EXPLAIN does not show the fired pattern")

    # drift: mid-query re-ranking vs the frozen static order
    d_on = _drift_db(n, reopt=True).sql(DRIFT_QUERY, explain=True)
    d_off = _drift_db(n, reopt=False).sql(DRIFT_QUERY)
    _assert_same_rows("drift", d_on, d_off)
    if d_on.stats.reranks < 1:
        raise AssertionError("drift run never re-ranked the select stack")
    if d_on.stats.llm_calls >= d_off.stats.llm_calls:
        raise AssertionError(
            f"re-ranked drift run made {d_on.stats.llm_calls} calls vs "
            f"static {d_off.stats.llm_calls} — expected a strict reduction")
    if d_on.stats.sim_latency_s >= d_off.stats.sim_latency_s:
        raise AssertionError(
            f"re-ranked makespan {d_on.stats.sim_latency_s:.2f}s vs static "
            f"{d_off.stats.sim_latency_s:.2f}s — expected a strict reduction")
    if "reopt: chunk" not in (d_on.plan or ""):
        raise AssertionError("EXPLAIN does not show the mid-query re-ranks")

    rows = []
    for name, r in (("dup_rewrite", r_on), ("dup_static", r_off),
                    ("drift_reopt", d_on), ("drift_static", d_off)):
        s = r.stats
        rows.append((
            f"rewrite.{name}",
            round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
            f"calls={s.llm_calls};makespan_s={s.sim_latency_s:.2f};"
            f"tokens={s.tokens};reranks={s.reranks};rows={len(r.table)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
