"""Table 7: SemanticMovies (D3) — logical optimizations at scale.
Q1 pi^s plots (refusal-prone: LOTUS aborts) | Q2 pi^s language from title |
Q3 sigma^s sentiment behind traditional filters+join | Q4 rho^s generation."""
from benchmarks.datasets import make_semanticmovies
from benchmarks.systems import (SYSTEMS, RefusalAbort, accuracy_f1, make_db)

Q1 = ("SELECT title, genre FROM LLM m (PROMPT 'extract the {genre VARCHAR} "
      "from the {{plot}}', Movie)")
Q2 = ("SELECT title, LLM m (PROMPT 'what is the {language VARCHAR} of "
      "{{title}}') AS language FROM Movie")
Q3 = ("SELECT review FROM Movie AS mv NATURAL JOIN Review AS rv WHERE "
      "LLM m (PROMPT 'is {{review}} {negative BOOLEAN}') = TRUE "
      "AND year >= 2015 AND title LIKE 'EN%'")
Q4 = ("SELECT category, description FROM LLM m (PROMPT 'list US rating "
      "categories {category VARCHAR} with {description VARCHAR}')")

QUERIES = {"Q1_project_plots": (Q1, "table_inference"),
           "Q2_project_title": (Q2, "project"),
           "Q3_select_filtered": (Q3, "select"),
           "Q4_generate": (Q4, "generate")}


def _score(qname, res, gt):
    t = res.table
    if qname == "Q1_project_plots":
        gold = {m["title"]: m["genre_gt"] for m in gt["movies"]}
        return accuracy_f1([r["genre"] for r in t.rows()],
                           [gold[r["title"]] for r in t.rows()])
    if qname == "Q2_project_title":
        gold = {m["title"]: m["lang_gt"] for m in gt["movies"]}
        return accuracy_f1([r["language"] for r in t.rows()],
                           [gold[r["title"]] for r in t.rows()])
    if qname == "Q3_select_filtered":
        keep_mids = {m["mid"] for m in gt["movies"]
                     if m["year"] >= 2015 and m["title"].startswith("EN")}
        gold = {r["review"] for r in gt["reviews"]
                if r["negative_gt"] and r["mid"] in keep_mids}
        got = set(t.column("review"))
        tp = len(got & gold)
        if tp == 0:
            return 0.0
        p, r_ = tp / max(1, len(got)), tp / max(1, len(gold))
        return 2 * p * r_ / (p + r_)
    if qname == "Q4_generate":
        return 1.0 if len(t) == 5 else max(0.0, 1 - abs(len(t) - 5) / 5)
    return 0.0


def run(quick: bool = False):
    tables, oracle, gt = make_semanticmovies(
        n_movies=150 if quick else 900, n_reviews=400 if quick else 2400)
    rows = []
    # refusals on graphic plots: only Q1 touches plots
    for qname, (q, kind) in QUERIES.items():
        refusal = 0.5 if qname == "Q1_project_plots" else 0.0
        for sysname in ("LOTUS", "BigQuery", "iPDB"):
            spec = SYSTEMS[sysname]
            if kind not in spec.supports:
                rows.append((f"semanticmovies.{qname}.{sysname}", None,
                             "status=N/A"))
                continue
            db = make_db(sysname, tables, oracle, error_rate=0.03,
                         refusal_rate=0.004 * (refusal > 0))
            try:
                res = db.sql(q)
            except RefusalAbort:
                rows.append((f"semanticmovies.{qname}.{sysname}", None,
                             "status=Exception (refused tuple fails LOTUS "
                             "pipeline)"))
                continue
            f1 = _score(qname, res, gt)
            s = res.stats
            rows.append((
                f"semanticmovies.{qname}.{sysname}",
                round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
                f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                f"tokens={s.tokens};rows_pred={s.rows_predicted};f1={f1:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
