"""Figure 3: intra-operator optimization ablation (dedup / row-marshaling),
sequential (1 worker) and parallel (16 workers)."""
from benchmarks.datasets import make_foodreviews
from benchmarks.systems import make_db

Q = ("SELECT rid, LLM m (PROMPT 'classify {{review}} {topic VARCHAR}') "
     "AS topic FROM FoodReview")

CONFIGS = {
    "unopt": {"use_dedup": False, "use_batching": False},
    "dedup": {"use_dedup": True, "use_batching": False},
    "marshal": {"use_dedup": False, "use_batching": True, "batch_size": 16},
    "dedup+marshal": {"use_dedup": True, "use_batching": True,
                      "batch_size": 16},
}


def run(quick: bool = False):
    tables, oracle, _ = make_foodreviews(n=220 if quick else 1014)
    # Fig 3 ablates dedup, which needs duplicate inputs (paper: joins and
    # stored tables naturally contain them) — duplicate every review once
    t = tables["FoodReview"]
    tables = {"FoodReview": t.concat(t)}
    rows = []
    for mode, workers in (("seq", 1), ("par16", 16)):
        for cname, copts in CONFIGS.items():
            db = make_db("iPDB", tables, oracle,
                         extra_options={**copts, "n_threads": workers,
                                        "enable_merge": False})
            res = db.sql(Q)
            s = res.stats
            rows.append((
                f"intraop.{mode}.{cname}",
                round(s.sim_latency_s / max(1, s.llm_calls) * 1e6, 1),
                f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                f"tokens={s.tokens};cache_hits={s.cache_hits}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
