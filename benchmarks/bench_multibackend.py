"""Multi-backend dispatch-overlap benchmark (worker-pool tentpole).

Workload: one query, two models — the in-process JAX engine (real local
compute) and an oracle executor standing in for a remote LLM API with a
real per-call wall-clock sleep (`sleep_per_call_s`).  With the default
`dispatch_workers = 1` every flush runs on the submitting thread, so the
query pays local compute + API wait serially.  With `dispatch_workers > 1`
the oracle queue's slices run on its backend worker lane (the JAX engine
stays synchronous: `max_concurrency = 1`), and the speculative kick after
each submitted window starts the API wait while the next window's local
inference is still running — the waits overlap compute AND each other.

Systems:
  sync    dispatch_workers=1 (the old synchronous flush)
  async   dispatch_workers=4, same max_dispatch / windows / chunking

The run asserts the acceptance criteria: identical rows and identical
deterministic accounting (llm_calls, tokens — batch composition is
invariant to worker count; the jax executor's modeled latency is measured
wall time, so sim_latency_s is reported but not compared bitwise) while
async wall-clock is strictly lower — the overlap made real time
disappear, not accounting.
"""
import time

from repro.core.database import IPDB
from repro.relational.table import Table

QUERY = ("SELECT name, "
         "LLM local (PROMPT 'guess the {color VARCHAR} of {{name}}') "
         "AS color, "
         "LLM remote (PROMPT 'rate {score INTEGER} for {{name}}') "
         "AS score FROM Items")


def oracle(instruction, rows):
    return [{"score": sum(map(ord, str(r.get("name", "")))) % 10}
            for r in rows]


def _db(n: int, workers: int, sleep_s: float) -> IPDB:
    db = IPDB()
    db.register_table("Items", Table.from_rows(
        [{"name": f"item {i}"} for i in range(n)]))
    db.register_oracle("api", oracle, sleep_per_call_s=sleep_s)
    db.sql("CREATE LLM MODEL remote PATH 'oracle:api' ON PROMPT")
    db.sql("CREATE LLM MODEL local PATH 'jax:olmo-1b' ON PROMPT "
           "OPTIONS { 'batch_size': 2, 'max_str': 6 }")
    db.set_option("batch_size", 2)
    db.set_option("chunk_size", 4)
    db.set_option("inflight_windows", 2)
    db.set_option("max_dispatch_calls", 2)
    db.set_option("dispatch_workers", workers)
    return db


def run(quick: bool = False):
    n = 8 if quick else 16
    sleep_s = 0.4 if quick else 0.5

    # untimed warmup: the first engine pays JIT compilation into the
    # process-global cache; without it the first timed system would look
    # slower for reasons that have nothing to do with dispatch
    warm = _db(n, 1, 0.0)
    warm.sql(QUERY)
    warm.close()

    walls = {}
    results = {}
    for name, workers in (("sync", 1), ("async", 4)):
        db = _db(n, workers, sleep_s)
        t0 = time.time()
        r = db.sql(QUERY)
        walls[name] = time.time() - t0
        results[name] = r
        if name == "async" and not db.inference_service.stats.async_batches:
            raise AssertionError("async run never used a worker lane")
        db.close()

    r_s, r_a = results["sync"], results["async"]
    if r_s.table.rows() != r_a.table.rows():
        raise AssertionError("worker-pool dispatch changed query results")
    if r_s.stats.llm_calls != r_a.stats.llm_calls:
        raise AssertionError(
            f"call count diverged: sync {r_s.stats.llm_calls} vs async "
            f"{r_a.stats.llm_calls} — batch composition must be invariant")
    if (r_s.stats.in_tokens, r_s.stats.out_tokens) != \
            (r_a.stats.in_tokens, r_a.stats.out_tokens):
        raise AssertionError(
            f"token accounting diverged: "
            f"{(r_s.stats.in_tokens, r_s.stats.out_tokens)} vs "
            f"{(r_a.stats.in_tokens, r_a.stats.out_tokens)}")
    overlap = walls["sync"] - walls["async"]
    if overlap <= 0.0:
        raise AssertionError(
            f"no wall-clock overlap: sync {walls['sync']:.2f}s vs async "
            f"{walls['async']:.2f}s")

    rows = []
    for name, r in (("sync", r_s), ("async", r_a)):
        s = r.stats
        rows.append((
            f"multibackend.{name}",
            round(walls[name] / max(1, s.llm_calls) * 1e6, 1),
            f"wall_s={walls[name]:.2f};calls={s.llm_calls};"
            f"makespan_s={s.sim_latency_s:.2f};rows={len(r.table)}"))
    rows.append(("multibackend.overlap", round(overlap * 1e6, 1),
                 f"overlap_s={overlap:.2f}"))
    return rows


if __name__ == "__main__":
    for row in run(quick=True):
        print(",".join(str(x) for x in row))
