"""Calibrated-cascade benchmark: a skewed semantic-predicate workload
where a cheap proxy confidently resolves most rows and only a small
uncertain band reaches the expensive model.

Workload: one boolean semantic projection over a table whose proxy is
right at confidence 0.95 on 7/8 of the rows and WRONG — but only at
confidence 0.3 — on the rest.  Under a 0.95-precision contract the
calibrated thresholds accept the confident band and escalate the rest,
so the expensive backend sees ~12.5% of the rows plus deterministic
audits.

Systems:
  direct       the expensive model answers every row (ground truth —
               the oracle's error_rate is 0)
  bootstrap    first cascade query on a cold store: escalate-everything,
               full direct cost + proxy scoring, buys the held-out
               agreement reservoir
  calibrated   the same database queried over DISJOINT rows: thresholds
               from the bootstrap evidence route only the uncertain band
               to the expensive model

The run asserts the acceptance criteria: the calibrated cascade's
expensive calls are <= 0.5x the direct route's AND the measured
precision (per-row agreement with direct) meets the declared contract.
"""
from repro.core.database import IPDB
from repro.relational.table import Table


def _mk(n):
    return [{"a": i, "txt": f"case {i}"} for i in range(n)]


def _i_of(row):
    return int(str(row.get("txt", "0")).split()[-1])


def truth(instruction, rows):
    return [{"flag": _i_of(r) % 3 == 0} for r in rows]


def proxy(instruction, rows):
    """Wrong exactly where unconfident: i % 8 == 0 rows get a flipped
    verdict at confidence 0.3, the rest are right at 0.95."""
    out = []
    for r in rows:
        i = _i_of(r)
        if i % 8 == 0:
            out.append({"flag": i % 3 != 0, "__confidence__": 0.3})
        else:
            out.append({"flag": i % 3 == 0, "__confidence__": 0.95})
    return out


PROMPT = "screen {flag BOOLEAN} of {{txt}}"
WITH = "WITH (cascade_proxy=small, cascade_target_precision=0.95)"


def _db(cascade: bool):
    db = IPDB()
    db.register_oracle("truth", truth)
    db.sql("CREATE LLM MODEL big PATH 'oracle:truth' ON PROMPT")
    if cascade:
        # the proxy is ~20x cheaper per call than the expensive model
        db.register_oracle("proxy", proxy,
                           latency_model=lambda i, o: 0.1)
        db.sql("CREATE LLM MODEL small PATH 'oracle:proxy' ON PROMPT")
    return db


def _q(lo, hi, with_clause=""):
    return (f"SELECT a, LLM big (PROMPT '{PROMPT}') {with_clause} AS flag "
            f"FROM T WHERE a >= {lo} AND a < {hi}")


def run(quick: bool = False):
    n = 96 if quick else 320
    half = n // 2
    # slice A (a < half) warms the calibration reservoir; slice B is
    # disjoint, so measurement prompts never hit the cross-query cache
    db_d = _db(cascade=False)
    db_d.register_table("T", Table.from_rows(_mk(n)))
    r_d = db_d.sql(_q(half, n))
    db_d.close()

    db_c = _db(cascade=True)
    db_c.register_table("T", Table.from_rows(_mk(n)))
    r_boot = db_c.sql(_q(0, half, WITH))
    r_c = db_c.sql(_q(half, n, WITH))
    db_c.close()

    want = {r["a"]: r["flag"] for r in r_d.table.rows()}
    got = {r["a"]: r["flag"] for r in r_c.table.rows()}
    if set(want) != set(got):
        raise AssertionError("cascade changed the output row set")
    precision = sum(want[a] == got[a] for a in want) / len(want)
    target = 0.95
    if precision < target:
        raise AssertionError(
            f"measured precision {precision:.3f} violates the "
            f"{target} contract")

    direct_calls = r_d.stats.llm_calls
    expensive_calls = r_c.stats.escalated_calls
    if r_c.stats.proxy_calls == 0:
        raise AssertionError("calibrated run never exercised the cascade")
    if expensive_calls > 0.5 * direct_calls:
        raise AssertionError(
            f"cascade made {expensive_calls} expensive calls vs "
            f"{direct_calls} direct — expected <= 0.5x")

    rows = []
    for name, r in (("direct", r_d), ("bootstrap", r_boot),
                    ("calibrated", r_c)):
        s = r.stats
        calls = max(1, s.llm_calls + s.escalated_calls)
        esc_frac = (s.escalated_rows / s.cascade_rows
                    if s.cascade_rows else 0.0)
        prec = precision if name == "calibrated" else 1.0
        rows.append((
            f"cascade.{name}",
            round(s.sim_latency_s / calls * 1e6, 1),
            f"llm_calls={s.llm_calls};proxy_calls={s.proxy_calls};"
            f"expensive_calls={s.escalated_calls};"
            f"escalated_frac={esc_frac:.3f};"
            f"makespan_s={s.sim_latency_s:.2f};"
            f"precision={prec:.3f};target={target}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
