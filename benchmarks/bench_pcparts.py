"""Table 5: PCParts (D1) Q1–Q5 across system emulations.

Q1 π^s table inference · Q2 ρ^s generation · Q3 π^s scalar ·
Q4 σ^s semantic select · Q5 ⋈^s semantic join.
"""
from __future__ import annotations

from benchmarks.datasets import make_pcparts
from benchmarks.systems import (SYSTEMS, RefusalAbort, accuracy_f1, f1_score,
                                make_db)

Q1 = ("SELECT name, vendor, socket FROM LLM m (PROMPT 'extract the "
      "{vendor VARCHAR} and {socket VARCHAR} from the {{description}}', "
      "Product)")
Q2 = ("SELECT tier, watts FROM LLM m (PROMPT 'list the standard PSU tiers "
      "{tier VARCHAR} and {watts INTEGER}')")
Q3 = ("SELECT name, LLM m (PROMPT 'get the {vendor VARCHAR} from "
      "{{description}}') AS vendor FROM Product")
Q4 = ("SELECT review FROM Product AS p NATURAL JOIN Review AS r WHERE "
      "LLM m (PROMPT 'is the sentiment of {{review}} {negative BOOLEAN}') "
      "= TRUE AND category = 'CPU'")
Q5 = ("SELECT c.name AS cpu, m.name AS mobo FROM Product AS c JOIN "
      "Product AS m ON "
      "LLM m (PROMPT 'is CPU {{c.description}} {compatible BOOLEAN} with "
      "motherboard {{m.description}}') WHERE c.category = 'CPU' AND "
      "m.category = 'Motherboard'")

QUERIES = {"Q1_project_table": (Q1, "table_inference"),
           "Q2_generate": (Q2, "generate"),
           "Q3_project_scalar": (Q3, "project"),
           "Q4_select": (Q4, "select"),
           "Q5_join": (Q5, "join")}


def _score(qname, res, gt, tables):
    if res is None:
        return 0.0
    t = res.table
    if qname == "Q1_project_table":
        gold = {p["name"]: (p["vendor_gt"], p["socket_gt"])
                for p in gt["products"]}
        pred = [(r["vendor"], r["socket"]) for r in t.rows()]
        gold_l = [gold[r["name"]] for r in t.rows()]
        return accuracy_f1([p[0] for p in pred], [g[0] for g in gold_l])
    if qname == "Q2_generate":
        return 1.0 if len(t) == 4 else max(0.0, 1 - abs(len(t) - 4) / 4)
    if qname == "Q3_project_scalar":
        gold = {p["name"]: p["vendor_gt"] for p in gt["products"]}
        return accuracy_f1([r["vendor"] for r in t.rows()],
                           [gold[r["name"]] for r in t.rows()])
    if qname == "Q4_select":
        cpu_pids = {p["pid"] for p in gt["products"]
                    if p["category"] == "CPU"}
        gold_reviews = {r["review"] for r in gt["reviews"]
                        if r["negative_gt"] and r["pid"] in cpu_pids}
        got = set(t.column("review"))
        tp = len(got & gold_reviews)
        if tp == 0:
            return 0.0
        prec = tp / max(1, len(got))
        rec = tp / max(1, len(gold_reviews))
        return 2 * prec * rec / (prec + rec)
    if qname == "Q5_join":
        byname = {p["name"]: p for p in gt["products"]}
        gold_pairs = set()
        for c in gt["products"]:
            if c["category"] != "CPU":
                continue
            for m in gt["products"]:
                if m["category"] == "Motherboard" and \
                        c["socket_gt"] == m["socket_gt"]:
                    gold_pairs.add((c["name"], m["name"]))
        cols = t.column_names
        got = set(zip(t.column(cols[0]), t.column(cols[1])))
        tp = len(got & gold_pairs)
        if tp == 0:
            return 0.0
        prec, rec = tp / max(1, len(got)), tp / max(1, len(gold_pairs))
        return 2 * prec * rec / (prec + rec)
    return 0.0


def run(quick: bool = False):
    tables, oracle, gt = make_pcparts(
        n_products=60 if quick else 220, n_reviews=200 if quick else 950)
    rows = []
    systems = ["LOTUS", "EvaDB", "Flock", "iPDB"]
    for qname, (q, kind) in QUERIES.items():
        if quick and qname == "Q5_join":
            continue
        for sysname in systems:
            spec = SYSTEMS[sysname]
            if kind not in spec.supports:
                rows.append((f"pcparts.{qname}.{sysname}", None,
                             "status=N/A"))
                continue
            db = make_db(sysname, tables, oracle, refusal_rate=0.0)
            try:
                res = db.sql(q)
            except RefusalAbort:
                rows.append((f"pcparts.{qname}.{sysname}", None,
                             "status=Exception"))
                continue
            f1 = _score(qname, res, gt, tables)
            s = res.stats
            per_call = (s.sim_latency_s / max(1, s.llm_calls)) * 1e6
            rows.append((
                f"pcparts.{qname}.{sysname}", round(per_call, 1),
                f"latency_s={s.sim_latency_s:.2f};calls={s.llm_calls};"
                f"tokens={s.tokens};f1={f1:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
