"""Synthetic benchmark datasets with ground truth + task oracles.

Mirrors the paper's D1 (PCParts), D2 (FoodReviews), D3 (SemanticMovies) and
the BioDex document workload at reduced-but-proportionate scale. Every
dataset ships its oracle (the "perfect model" the OracleExecutor perturbs)
and its ground-truth frame for F1 scoring.
"""
from __future__ import annotations

import numpy as np

from repro.relational.table import Table


def getcol(row: dict, name: str, default=""):
    """Suffix-robust column lookup: binder aliases columns to a__name."""
    if name in row:
        return row[name]
    for k, v in row.items():
        if k.endswith("__" + name):
            return v
    return default

VENDORS = ["Intel", "AMD", "ASUS", "MSI", "Corsair", "Gigabyte", "EVGA"]
SOCKETS = ["LGA1700", "AM5", "AM4", "LGA1200"]


# ------------------------------- D1: PCParts ----------------------------------
def make_pcparts(seed: int = 0, n_products: int = 220, n_reviews: int = 950):
    rng = np.random.default_rng(seed)
    cats = (["CPU"] * 40 + ["Motherboard"] * 40 + ["GPU"] * 40 +
            ["PSU"] * 50 + ["RAM"] * 50)[:n_products]
    products = []
    for i, cat in enumerate(cats):
        vendor = VENDORS[rng.integers(0, len(VENDORS))]
        if cat == "CPU":
            vendor = ["Intel", "AMD"][rng.integers(0, 2)]
        socket = SOCKETS[rng.integers(0, len(SOCKETS))]
        if cat == "CPU" and vendor == "Intel":
            socket = ["LGA1700", "LGA1200"][rng.integers(0, 2)]
        if cat == "CPU" and vendor == "AMD":
            socket = ["AM5", "AM4"][rng.integers(0, 2)]
        products.append({
            "pid": i,
            "name": f"{vendor} {cat}-{i}",
            "category": cat,
            "description": f"{vendor} {cat.lower()} unit {i} socket {socket} "
                           f"performance tier {int(rng.integers(1, 5))}",
            "vendor_gt": vendor, "socket_gt": socket,
            "price": float(rng.integers(40, 900)),
        })
    reviews = []
    for i in range(n_reviews):
        pid = int(rng.integers(0, n_products))
        neg = bool(rng.uniform() < 0.3)
        text = ("terrible, ran hot and died" if neg
                else "works great, very happy")
        reviews.append({"rid": i, "pid": pid,
                        "review": f"{text} (case {i % 37})",
                        "negative_gt": neg})

    prod_t = Table.from_rows([{k: v for k, v in p.items()
                               if not k.endswith("_gt")} for p in products])
    rev_t = Table.from_rows([{k: v for k, v in r.items()
                              if not k.endswith("_gt")} for r in reviews])

    def oracle(instruction, rows):
        out = []
        for r in rows:
            o = {}
            desc = str(getcol(r, "description")) or str(getcol(r, "name"))
            for v in VENDORS:
                if v in desc or v in str(getcol(r, "name")):
                    o["vendor"] = v
                    break
            else:
                o["vendor"] = "unknown"
            for s in SOCKETS:
                if s in desc:
                    o["socket"] = s
                    break
            else:
                o["socket"] = "unknown"
            rv = str(getcol(r, "review"))
            o["negative"] = ("terrible" in rv) or ("died" in rv)
            # semantic join: CPU/motherboard compatibility by socket token
            d1 = str(r.get("c__description", getcol(r, "description")))
            d2 = str(r.get("m__description", ""))
            s1 = next((s for s in SOCKETS if s in d1), "x")
            s2 = next((s for s in SOCKETS if s in d2), "y")
            o["compatible"] = s1 == s2
            out.append(o)
        if "PSU tiers" in instruction and not rows:
            return [{"tier": t, "watts": w} for t, w in
                    [("bronze", 450), ("silver", 550), ("gold", 750),
                     ("platinum", 1000)]]
        return out

    gt = {"products": products, "reviews": reviews}
    return {"Product": prod_t, "Review": rev_t}, oracle, gt


# ----------------------------- D2: FoodReviews --------------------------------
def make_foodreviews(seed: int = 1, n: int = 1014):
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        is_food = bool(rng.uniform() < 0.55)
        text = (f"the burger and fries were {'cold' if rng.uniform()<.4 else 'tasty'}"
                if is_food else
                f"the staff was {'rude' if rng.uniform()<.4 else 'friendly'} at the counter")
        # unique visit tag per review (real review texts are unique —
        # keeps T6 call counts comparable: 1014/16 = 64 marshaled calls)
        rows.append({"rid": i, "review": f"{text} #visit{i}",
                     "label_gt": "food" if is_food else "service"})
    t = Table.from_rows([{"rid": r["rid"], "review": r["review"]}
                         for r in rows])

    def oracle(instruction, rws):
        return [{"topic": "food" if any(w in str(getcol(r, "review"))
                                        for w in ("burger", "fries"))
                 else "service"} for r in rws]

    return {"FoodReview": t}, oracle, rows


# --------------------------- D3: SemanticMovies --------------------------------
GENRES = ["drama", "comedy", "horror", "action", "romance"]
LANGS = ["English", "French", "Spanish", "Japanese"]


def make_semanticmovies(seed: int = 2, n_movies: int = 900,
                        n_reviews: int = 2400, n_cast: int = 1200):
    rng = np.random.default_rng(seed)
    movies = []
    for i in range(n_movies):
        g = GENRES[rng.integers(0, len(GENRES))]
        lang = LANGS[rng.integers(0, len(LANGS))]
        graphic = bool(rng.uniform() < 0.04)        # triggers LOTUS refusals
        movies.append({
            "mid": i, "title": f"{lang[:2].upper()}-Film-{i}",
            "plot": (f"{'graphic violence ' if graphic else ''}a {g} story "
                     f"about case {i % 211} told in {lang}"),
            "year": int(rng.integers(1980, 2024)),
            "genre_gt": g, "lang_gt": lang, "graphic_gt": graphic})
    reviews = []
    for i in range(n_reviews):
        mid = int(rng.integers(0, n_movies))
        neg = bool(rng.uniform() < 0.35)
        reviews.append({"rid": i, "mid": mid,
                        "review": ("dull and disappointing" if neg else
                                   "brilliant and moving") + f" r{i % 97}",
                        "negative_gt": neg})
    cast = []
    for i in range(n_cast):
        cast.append({"mid": int(rng.integers(0, n_movies)),
                     "cname": f"person{i % 120}",
                     "role": "Director" if i % 6 == 0 else "Actor"})

    t_movies = Table.from_rows([{k: v for k, v in m.items()
                                 if not k.endswith("_gt")} for m in movies])
    t_reviews = Table.from_rows([{k: v for k, v in r.items()
                                  if not k.endswith("_gt")} for r in reviews])
    t_cast = Table.from_rows(cast)

    def oracle(instruction, rows):
        out = []
        for r in rows:
            o = {}
            plot = str(getcol(r, "plot"))
            title = str(getcol(r, "title"))
            o["genre"] = next((g for g in GENRES if g in plot), "drama")
            o["language"] = next(
                (l for l in LANGS if l in plot),
                next((l for l in LANGS if title.startswith(l[:2].upper())),
                     "English"))
            rv = str(getcol(r, "review"))
            o["negative"] = "disappointing" in rv or "dull" in rv
            o["rating"] = "R" if "violence" in plot else "PG"
            out.append(o)
        if "rating categories" in instruction and not rows:
            return [{"category": c, "description": f"desc {c}"} for c in
                    ("G", "PG", "PG-13", "R", "NC-17")]
        return out

    gt = {"movies": movies, "reviews": reviews}
    return {"Movie": t_movies, "Review": t_reviews, "CastT": t_cast}, oracle, gt


# ------------------------------- BioDex-like -----------------------------------
REACTIONS = [f"reaction_{i}" for i in range(200)]


def make_biodex(seed: int = 3, n_docs: int = 400):
    rng = np.random.default_rng(seed)
    docs = []
    for i in range(n_docs):
        k = int(rng.integers(1, 6))
        labels = list(rng.choice(len(REACTIONS), size=k, replace=False))
        body = " ".join(f"patient exhibited {REACTIONS[l]}" for l in labels)
        docs.append({"did": i,
                     "article": f"case report {i}: {body} after drug X",
                     "labels_gt": [REACTIONS[l] for l in labels]})
    t = Table.from_rows([{"did": d["did"], "article": d["article"]}
                         for d in docs])

    def oracle(instruction, rows):
        out = []
        for r in rows:
            art = str(getcol(r, "article"))
            found = [x for x in REACTIONS if x + " " in art + " "]
            out.append({"reactions": ", ".join(found[:5])})
        return out

    return {"BioDex": t}, oracle, docs
