"""End-to-end serving driver: a small model serving batched requests with
continuous batching, grammar-constrained decoding, and shared-prefix KV
reuse — the engine that PREDICT drives, exercised directly.

    PYTHONPATH=src python examples/serve_e2e.py [--arch olmo-1b] [--n 12]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import repro.configs as C
from repro.serving.engine import InferenceEngine
from repro.serving.grammar import Field, JsonGrammar
from repro.serving.scheduler import ContinuousBatcher, Request


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = C.get_smoke_config(args.arch).replace(vocab_size=259)
    print(f"model: {args.arch} (smoke config, "
          f"{cfg.num_layers}L d={cfg.d_model})")
    eng = InferenceEngine(cfg, max_len=512)

    # 1) batched generate with a shared instruction prefix (KV reuse)
    g = JsonGrammar([Field("sentiment", "BOOLEAN"),
                     Field("topic", "VARCHAR")], max_str=8)
    prefix = ("SYSTEM: You are a review classifier. Return JSON with "
              "sentiment and topic.\n")
    prompts = [f"review {i}: this product is great" for i in range(4)]
    t0 = time.time()
    res = eng.generate(prompts, grammar=g, shared_prefix=prefix,
                       max_new_tokens=64, temperature=0.8)
    print(f"\nbatched generate ({len(prompts)} reqs, shared prefix): "
          f"{time.time()-t0:.2f}s wall")
    for p, t in zip(prompts, res.texts):
        print(f"  {p[:24]!r} -> {t}")
    print(f"  prefill_tokens={res.stats.prefill_tokens} "
          f"decode_steps={res.stats.decode_steps}")

    res2 = eng.generate(["another review"], grammar=g, shared_prefix=prefix,
                        max_new_tokens=64)
    print(f"  2nd call prefix-hit={res2.stats.prefix_hits} "
          f"prefill_tokens={res2.stats.prefill_tokens} (prefix reused)")

    # 2) continuous batching over a request stream
    reqs = [Request(prompt=f"classify item {i}", grammar=g,
                    max_new_tokens=64) for i in range(args.n)]
    cb = ContinuousBatcher(eng, num_slots=args.slots)
    t0 = time.time()
    done = cb.run(reqs, temperature=0.9)
    dt = time.time() - t0
    ok = sum(1 for r in done if r.text and not r.error)
    print(f"\ncontinuous batching: {len(reqs)} requests on "
          f"{args.slots} slots in {dt:.2f}s ({ok} ok)")
    print(f"  ticks={cb.stats.decode_steps} "
          f"tokens out={cb.stats.output_tokens}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
