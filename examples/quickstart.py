"""Quickstart: semantic SQL over a product table.

    PYTHONPATH=src python examples/quickstart.py

Registers a table, uploads two models (a deterministic oracle playing the
remote-API role, and a REAL tiny JAX model with grammar-forced generation),
then runs the paper's core query shapes end-to-end.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.database import IPDB
from repro.relational.table import Table


def main() -> None:
    db = IPDB()
    db.register_table("Product", Table.from_rows([
        {"name": "Intel Core i7-9700K", "category": "CPU", "price": 350.0},
        {"name": "AMD Ryzen 5 5600X", "category": "CPU", "price": 280.0},
        {"name": "ASUS ROG Z390-A", "category": "Motherboard", "price": 180.0},
        {"name": "MSI B550 Tomahawk", "category": "Motherboard", "price": 160.0},
        {"name": "Corsair RM750x", "category": "PSU", "price": 110.0},
    ]))

    # --- a "remote" model (oracle-backed, like an OpenAI-compatible API) ---
    def orc(instruction, rows):
        out = []
        for r in rows:
            name = str(r.get("name", ""))
            out.append({"vendor": next((v for v in
                                        ("Intel", "AMD", "ASUS", "MSI",
                                         "Corsair") if v in name), "?"),
                        # "budget part" world knowledge lives in the model
                        "budget": any(t in name for t in
                                      ("B550", "RM750", "5600X"))})
        return out

    db.register_oracle("catalog", orc)
    db.sql("CREATE LLM MODEL o4mini PATH 'oracle:catalog' ON PROMPT "
           "API 'https://api.openai.com/v1/'")

    print("== semantic projection (table inference) ==")
    r = db.sql("SELECT name, vendor FROM LLM o4mini (PROMPT "
               "'extract the {vendor VARCHAR} from {{name}}', Product)")
    print(r.table.head_repr())
    print(f"stats: calls={r.stats.llm_calls} tokens={r.stats.tokens}\n")

    print("== semantic selection with predict pull-up ==")
    q = ("SELECT name, price FROM Product WHERE LLM o4mini (PROMPT "
         "'is {{name}} a {budget BOOLEAN} part?') = TRUE "
         "AND category = 'Motherboard'")
    print(db.explain(q))
    r = db.sql(q)
    print(r.table.head_repr())
    print(f"stats: calls={r.stats.llm_calls} (only motherboards inferred)\n")

    print("== the same query on a REAL tiny JAX model "
          "(grammar-forced generation) ==")
    db.sql("CREATE LLM MODEL tiny PATH 'jax:olmo-1b' ON PROMPT "
           "OPTIONS { 'batch_size': 4, 'max_str': 8 }")
    r = db.sql("SELECT name, LLM tiny (PROMPT 'guess a {color VARCHAR} "
               "for {{name}}') AS color FROM Product")
    print(r.table.head_repr())
    print("(random weights → nonsense values, but 100% schema-compliant "
          "thanks to grammar-forced decoding)")


if __name__ == "__main__":
    main()
