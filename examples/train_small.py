"""Train a ~100M-parameter model for a few hundred steps (CPU-scaled by
default; pass --full-100m on real hardware).

    PYTHONPATH=src python examples/train_small.py [--steps 200]

This drives repro.launch.train (checkpointing, preemption handling,
straggler detection included).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.train import main as train_main


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true",
                    help="olmo-1b geometry at 8 layers (~100M class); "
                    "CPU default uses the smoke config")
    ap.add_argument("--ckpt-dir", default="/tmp/ipdb_train_small")
    args = ap.parse_args()

    argv = ["--arch", "olmo-1b", "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--batch", "8", "--seq-len", "128", "--lr", "3e-3"]
    if not args.full_100m:
        argv.append("--smoke")
    return train_main(argv)


if __name__ == "__main__":
    sys.exit(main())
