"""Semantic join + LLM AGG + table generation (paper Table 1 Q3/Q5/Q6).

    PYTHONPATH=src python examples/semantic_join.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.database import IPDB
from repro.relational.table import Table


def main() -> None:
    db = IPDB()
    db.register_table("Movie", Table.from_rows([
        {"title": "Titanic", "plot": "romance and tragedy at sea"},
        {"title": "Alien", "plot": "graphic violence in deep space"},
        {"title": "Toy Story", "plot": "family fun with living toys"},
    ]))
    db.register_table("CastT", Table.from_rows([
        {"title": "Titanic", "cname": "James Cameron", "role": "Director"},
        {"title": "Alien", "cname": "Ridley Scott", "role": "Director"},
    ]))

    def orc(instruction, rows):
        if "maturity" in instruction and not rows:
            return [{"label": l, "description": d} for l, d in
                    [("G", "family friendly for all ages"),
                     ("R", "graphic violence or adult themes")]]
        out = []
        for r in rows:
            vals = " ".join(str(v) for v in r.values())
            out.append({
                "match": ("violence" in vals) == ("violence" in
                                                  str(r.get("m__plot", ""))
                                                  + str(r.get("plot", "")))
                and (("violence" in vals) or ("family" in vals)),
                "style": "epic" if "romance" in vals else "tense",
            })
        return out

    db.register_oracle("movies", orc)
    db.sql("CREATE LLM MODEL gem PATH 'oracle:movies' ON PROMPT")

    print("== table generation (semantic relation ρ^s) ==")
    r = db.sql("CREATE TABLE MaturityRating AS SELECT label, description "
               "FROM LLM gem (PROMPT 'Get all the maturity {label VARCHAR} "
               "and {description VARCHAR} in US')")
    print(r.table.head_repr())

    print("\n== semantic join (⋈^s): movie plots × rating descriptions ==")
    r = db.sql("SELECT m.title AS title, mr.label AS rating FROM Movie AS m "
               "JOIN MaturityRating AS mr ON LLM gem (PROMPT 'is rating "
               "{{mr.description}} depicted in {{m.plot}}')")
    print(r.table.head_repr())
    print(f"stats: calls={r.stats.llm_calls} tokens={r.stats.tokens} "
          f"(dedup hits={r.stats.cache_hits})")

    print("\n== semantic aggregate (LLM AGG) ==")
    r = db.sql("SELECT cname, LLM AGG gem (PROMPT 'summarize the "
               "cinematography {style VARCHAR} of the {{plot}}s') AS style "
               "FROM CastT NATURAL JOIN Movie GROUP BY cname")
    print(r.table.head_repr())


if __name__ == "__main__":
    main()
